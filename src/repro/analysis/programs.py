"""Representative engine programs for the jaxpr audit (Layer 2).

One builder, parameterized the way the engine is: fleet x heuristic x
dispatcher x observers x dynamics. Returns ``(fn, args)`` ready for
``jax.make_jaxpr(fn)(*args)`` — the same construction path as
``tests/test_compile_flatness.py`` and the production runner, so what
the audit traces is what CI ships.

JAX is imported lazily inside the builders: importing
:mod:`repro.analysis` (and running Layer 1) must work on the JAX-less
lint runner.
"""
from __future__ import annotations

from typing import Sequence, Tuple

#: The default audit matrix: the paper pair on the heaviest builtins,
#: once bare and once with the full observer + faults stack (the aux
#: paths are where weak-type promotions hide).
DEFAULT_PROGRAMS: Tuple[Tuple[str, dict], ...] = (
    ("paper_x2/ELARE", dict(fleet="paper_x2", heuristic="ELARE")),
    ("paper_x2/FELARE", dict(fleet="paper_x2", heuristic="FELARE")),
    ("paper_x2/FELARE+aux", dict(
        fleet="paper_x2", heuristic="FELARE",
        observers=("timeline", "task_log", "health"),
        dynamics="bernoulli_updown")),
    ("tiered_x4/FELARE+net", dict(
        fleet="tiered_x4", heuristic="FELARE",
        dispatcher="tier_aware", network="tiered",
        observers=("network", "task_log"))),
    ("paper_x2/FELARE+pallas", dict(
        fleet="paper_x2", heuristic="FELARE", pallas_map=True)),
)


def simulator_program(fleet: str = "paper_x2", heuristic: str = "FELARE",
                      dispatcher: str = "fair_spill",
                      observers: Sequence[str] = (),
                      dynamics: str | None = None,
                      network: str | None = None,
                      pallas_map: bool = False,
                      n_tasks: int = 24, seed: int = 0, rate: float = 4.0):
    """Build ``(simulate, (trace,))`` for one engine configuration.

    ``pallas_map=True`` routes the map decision and the dispatcher's
    balance scan through the fused Pallas kernels
    (:func:`repro.core.policy.with_pallas_map` /
    :func:`repro.core.dispatch.with_pallas_balance`) — the same toggle as
    ``SweepSpec.use_pallas_map`` — so the audit covers the kernel path's
    dtypes/effects/flatness too.
    """
    import jax

    from repro import scenarios
    from repro.core import dispatch, engine, faults, observe, policy, workload
    from repro.core import network as network_mod

    system = scenarios.get_fleet(fleet).build()
    pol = policy.get(heuristic)
    disp = dispatch.resolve(dispatcher)
    if pallas_map:
        pol = policy.with_pallas_map(pol)
        disp = dispatch.with_pallas_balance(disp)
    sim = engine.make_simulator(
        pol, system.as_jax(),
        queue_size=system.queue_size,
        fairness_factor=float(system.fairness_factor),
        dispatcher=disp,
        site_of_machine=system.sites,
        observers=observe.resolve(observers),
        dynamics=faults.resolve(dynamics) if dynamics is not None else None,
        network=(network_mod.resolve(network) if network is not None
                 else None),
        tier_of_site=system.tiers,
    )
    trace = workload.poisson_trace(
        jax.random.PRNGKey(seed), n_tasks, rate, system.eet)
    return sim, (trace,)


def trace_program(name: str, params):
    """``(name, closed_jaxpr, out_shapes)`` for one audit-matrix entry.

    ``params`` is either a kwargs dict for :func:`simulator_program` or a
    zero-arg callable returning ``(fn, args)`` — the latter lets tests
    audit seeded-bad programs through the same checks.
    """
    import jax

    fn, args = params() if callable(params) else simulator_program(**params)
    closed = jax.make_jaxpr(fn)(*args)
    out_shapes = jax.eval_shape(fn, *args)
    return name, closed, out_shapes
