"""Layer 2: jaxpr audit — discipline rules checked on the traced program.

Where Layer 1 reads source, Layer 2 reads what jit actually saw: it
traces representative engine programs (:mod:`repro.analysis.programs`)
and walks the jaxprs with the shared visitor
(:mod:`repro.roofline.jaxpr_walk`). Four rules:

  JX101 jaxpr-flatness   the site count F is data, not program — the
                         recursive equation count and primitive multiset
                         of the simulator are identical across fleets
                         (the reusable form of
                         ``tests/test_compile_flatness.py``).
  JX102 jaxpr-dtype      no float64/complex128 anywhere in the traced
                         program, and no weak-typed floating output —
                         weak types re-promote under ``jax_enable_x64``
                         and silently de-pair CRN comparisons.
  JX103 jaxpr-effects    no callback/debug effect primitives inside the
                         loop (``pure_callback``, ``io_callback``,
                         ``debug_callback``, ``debug_print``) — each one
                         is a host round-trip per step.
  JX104 retrace-audit    replay the runner trace log: every (policy x
                         scenario x dispatcher x dynamics x network)
                         tuple traces exactly once across a repeated
                         sweep.

JAX is imported lazily inside ``run()`` — importing this module (so the
checks register for ``--list-checks``) works on the JAX-less lint
runner; *running* a Layer 2 check without JAX reports a single
structured finding instead of crashing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.analysis import registry as _registry
from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.programs import DEFAULT_PROGRAMS, simulator_program

#: Effect primitives that smuggle host work into the loop.
EFFECT_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "callback", "outside_call", "host_callback_call",
})


def _no_jax(check, rule) -> List[Finding]:
    return [Finding(
        path=f"jaxpr:{check}", line=0, rule=rule, check=check,
        message="JAX unavailable — Layer 2 requires the full runtime "
                "(run on the tests runner, not the lint runner)")]


def _path_str(name: str, path: Tuple[int, ...]) -> str:
    return f"jaxpr:{name}:" + ".".join(str(i) for i in path)


@dataclasses.dataclass(frozen=True)
class FlatnessCheck:
    """JX101: primitive-multiset equality of the simulator across F.

    Three fleet groups are compared independently (programs are only
    expected to match *within* a group): the flat federation pair, the
    tiered pair with the network subsystem attached — the transfer
    arithmetic must be as site-count-flat as the rest of the loop — and
    the federation pair again on the fused Pallas map/balance path,
    whose lane-padded kernels must keep the grid shape (and so the
    program) independent of the machine count.
    """

    name: str = "jaxpr-flatness"
    rule: str = "JX101"
    layer: int = 2
    fleets: Tuple[str, ...] = ("paper_x2", "paper_x32")
    heuristic: str = "FELARE"
    dispatcher: str = "fair_spill"
    tiered_fleets: Tuple[str, ...] = ("tiered_x4", "tiered_x16")
    tiered_dispatcher: str = "tier_aware"
    tiered_network: str = "tiered"
    pallas_fleets: Tuple[str, ...] = ("paper_x2", "paper_x32")

    def _compare_group(self, fleets, dispatcher, network,
                       pallas_map=False) -> List[Finding]:
        import jax

        from repro.roofline.jaxpr_walk import count_eqns, primitive_counts

        out: List[Finding] = []
        baseline = None
        for fleet in fleets:
            fn, args = simulator_program(
                fleet=fleet, heuristic=self.heuristic,
                dispatcher=dispatcher, network=network,
                pallas_map=pallas_map)
            jx = jax.make_jaxpr(fn)(*args).jaxpr
            stats = (fleet, count_eqns(jx), primitive_counts(jx))
            if baseline is None:
                baseline = stats
                continue
            f0, n0, p0 = baseline
            f1, n1, p1 = stats
            if n0 != n1:
                out.append(Finding(
                    path=f"jaxpr:{f1}/{self.heuristic}", line=0,
                    rule=self.rule, check=self.name,
                    message=(f"site count leaked into the program: "
                             f"{n1} equations at {f1} vs {n0} at {f0}")))
            for prim in sorted(set(p0) | set(p1)):
                if p0.get(prim, 0) != p1.get(prim, 0):
                    out.append(Finding(
                        path=f"jaxpr:{f1}/{self.heuristic}", line=0,
                        rule=self.rule, check=self.name,
                        message=(f"primitive multiset differs at {prim}: "
                                 f"{p1.get(prim, 0)} at {f1} vs "
                                 f"{p0.get(prim, 0)} at {f0}")))
        return out

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        try:
            import jax  # noqa: F401
        except ImportError:
            return _no_jax(self.name, self.rule)
        out = self._compare_group(self.fleets, self.dispatcher, None)
        out += self._compare_group(
            self.tiered_fleets, self.tiered_dispatcher, self.tiered_network)
        out += self._compare_group(
            self.pallas_fleets, self.dispatcher, None, pallas_map=True)
        return out


@dataclasses.dataclass(frozen=True)
class DtypeAuditCheck:
    """JX102: no float64/complex128 avals; no weak-typed float outputs."""

    name: str = "jaxpr-dtype"
    rule: str = "JX102"
    layer: int = 2

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        try:
            import jax
        except ImportError:
            return _no_jax(self.name, self.rule)
        from jax.tree_util import tree_flatten_with_path

        from repro.analysis.programs import trace_program
        from repro.roofline.jaxpr_walk import iter_eqns

        out: List[Finding] = []
        for pname, params in DEFAULT_PROGRAMS:
            name, closed, out_shapes = trace_program(pname, params)
            seen = set()
            for eqn, path in iter_eqns(closed.jaxpr):
                for v in eqn.outvars:
                    dt = getattr(getattr(v, "aval", None), "dtype", None)
                    if dt is None:
                        continue
                    if str(dt) in ("float64", "complex128", "int64"):
                        key = (eqn.primitive.name, str(dt))
                        if key in seen:
                            continue  # one finding per (prim, dtype)
                        seen.add(key)
                        out.append(Finding(
                            path=_path_str(name, path), line=0,
                            rule=self.rule, check=self.name,
                            message=(f"{dt} value produced by "
                                     f"{eqn.primitive.name} — the engine "
                                     "contract is float32/int32 "
                                     "throughout")))
            leaves, _ = tree_flatten_with_path(out_shapes)
            for keypath, leaf in leaves:
                dt = getattr(leaf, "dtype", None)
                if dt is None:
                    continue
                kp = "".join(str(k) for k in keypath)
                if str(dt) in ("float64", "complex128"):
                    out.append(Finding(
                        path=f"jaxpr:{name}:out{kp}", line=0,
                        rule=self.rule, check=self.name,
                        message=f"output {kp} has dtype {dt}"))
                elif (getattr(leaf, "weak_type", False)
                      and jax.numpy.issubdtype(dt, jax.numpy.floating)):
                    out.append(Finding(
                        path=f"jaxpr:{name}:out{kp}", line=0,
                        rule=self.rule, check=self.name,
                        message=(f"output {kp} is weak-typed {dt} — a "
                                 "python-scalar-derived value whose dtype "
                                 "flips under jax_enable_x64; anchor it "
                                 "with jnp.float32(...)")))
        return out


@dataclasses.dataclass(frozen=True)
class EffectsAuditCheck:
    """JX103: no callback/debug effect primitives in the traced loop."""

    name: str = "jaxpr-effects"
    rule: str = "JX103"
    layer: int = 2

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        try:
            import jax  # noqa: F401
        except ImportError:
            return _no_jax(self.name, self.rule)
        from repro.analysis.programs import trace_program
        from repro.roofline.jaxpr_walk import iter_eqns

        out: List[Finding] = []
        for pname, params in DEFAULT_PROGRAMS:
            name, closed, _ = trace_program(pname, params)
            for eqn, path in iter_eqns(closed.jaxpr):
                if eqn.primitive.name in EFFECT_PRIMITIVES:
                    out.append(Finding(
                        path=_path_str(name, path), line=0,
                        rule=self.rule, check=self.name,
                        message=(f"effect primitive {eqn.primitive.name} "
                                 "inside the traced loop — a host round-"
                                 "trip per step; use an observer or "
                                 "post-hoc analysis instead")))
        return out


@dataclasses.dataclass(frozen=True)
class RetraceAuditCheck:
    """JX104: replay the runner trace log — one trace per config tuple.

    Replays a multi-config sweep sequence (two dispatchers x two
    policies, all distinct tuples) and fails on any (policy x scenario x
    dispatcher x dynamics x network) tuple appearing in the trace log
    more than once. A duplicate means something traced twice for one config — a
    policy object rebuilt un-hashably mid-sweep, a vmap falling out of
    the single jit, a dispatcher leaking per-call state — i.e. the
    single-jit contract ``tests/test_compile_flatness.py`` pins, checked
    as an analysis.
    """

    name: str = "retrace-audit"
    rule: str = "JX104"
    layer: int = 2
    heuristics: Tuple[str, ...] = ("ELARE", "FELARE")
    fleet: str = "paper_x2"
    dispatchers: Tuple[str, ...] = ("round_robin", "fair_spill")
    n_tasks: int = 24

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        try:
            import jax  # noqa: F401
        except ImportError:
            return _no_jax(self.name, self.rule)
        from repro import experiments
        from repro.experiments import runner

        runner._TRACE_LOG.clear()
        for dispatcher in self.dispatchers:
            experiments.run_sweep(experiments.SweepSpec(
                system=self.fleet, rates=(3.0,), reps=2,
                n_tasks=self.n_tasks, heuristics=self.heuristics, seed=1,
                dispatcher=dispatcher))
        log = list(runner._TRACE_LOG)
        runner._TRACE_LOG.clear()

        out: List[Finding] = []
        counts: dict = {}
        for tup in log:
            counts[tup] = counts.get(tup, 0) + 1
        for tup, n in sorted(counts.items()):
            if n > 1:
                out.append(Finding(
                    path=f"jaxpr:retrace:{'x'.join(tup)}", line=0,
                    rule=self.rule, check=self.name,
                    message=(f"config tuple {tup} traced {n} times in one "
                             "sweep replay — a simulator fell out of the "
                             "single jit for this config")))
        expected = {(h, "poisson", d, "none", "none")
                    for h in self.heuristics for d in self.dispatchers}
        for tup in sorted(expected - set(counts)):
            out.append(Finding(
                path=f"jaxpr:retrace:{'x'.join(tup)}", line=0,
                rule=self.rule, check=self.name,
                message=(f"expected config tuple {tup} never traced — "
                         "trace-log instrumentation drifted")))
        return out


for _name, _check in [
    ("jaxpr-flatness", FlatnessCheck()),
    ("jaxpr-dtype", DtypeAuditCheck()),
    ("jaxpr-effects", EffectsAuditCheck()),
    ("retrace-audit", RetraceAuditCheck()),
]:
    _registry.register(_name, _check)
del _name, _check
