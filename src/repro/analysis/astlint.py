"""Layer 1: AST lint — jit-discipline rules that need no JAX import.

Five repo-specific rules over ``src/repro/core/`` and
``src/repro/scenarios/`` (the code that ends up inside the single jit or
feeds it static configuration):

  JD001 registry-frozen   registered objects must be frozen dataclasses
                          (or NamedTuples) with hashable field types —
                          they are jit static-argument cache keys.
  JD002 crn-discipline    no ``jax.random.PRNGKey``/``split`` outside the
                          sanctioned CRN helpers; ad-hoc key material
                          breaks common-random-number pairing.
  JD003 host-effects      no host-side effects (``time.*``,
                          ``np.random.*``, ``print``, ``datetime``,
                          ``jax.debug``) inside jit-body functions.
  JD004 traced-branch     no Python ``if``/``while`` on traced values in
                          jit bodies (including ``bool()``/``int()``
                          coercions) — they retrace or crash under jit.
  JD005 oracle-f32        the pyengine oracle must keep every mirrored
                          decision quantity in ``np.float32``; a stray
                          float64 literal silently de-pairs the oracle
                          from the engine at ULP scale.

Everything here is pure ``ast`` — importable (and correct) on the CI
lint runner, which has ruff and nothing else. Escape hatches are the
``# repro: allow-<name>[reason]`` annotations parsed by
:mod:`repro.analysis.config`; a marker with no ``[reason]`` is itself a
finding.

Heuristics, stated honestly: "jit body" is resolved by NAME — engine
stage functions (``_stage_*`` and the ``make_simulator`` inner
functions), the protocol methods the registries dispatch on
(``__call__``, ``step``, ``select``, ``nominate``, ``key``, ``drop``,
``dispatch``, ``on_event``, ``init``, ``finalize``, ``sample``) — plus
any function opted in with a ``# repro: jit-body`` marker on its ``def``
line. Taint for JD004 starts from the parameter names the engine
actually passes traced values under (``st``, ``ctx``, ``key``, ...), so
``self`` (a frozen config) and static closure parameters stay
branchable. A helper that only ever runs traced but matches neither net
is a coverage gap, not a false positive — mark it ``jit-body``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import registry as _registry
from repro.analysis.config import AnalysisConfig, line_markers
from repro.analysis.findings import Finding

#: Repo-relative directories Layer 1 scans.
SCOPE_DIRS = ("src/repro/core", "src/repro/scenarios")

#: Method names the engine/registries invoke on traced values.
JIT_BODY_METHODS = frozenset({
    "__call__", "step", "select", "nominate", "key", "drop", "dispatch",
    "on_event", "init", "finalize", "sample",
})

#: Free-function names that are jit bodies (``make_simulator`` inners).
JIT_BODY_FUNCS = frozenset({"body", "cond", "simulate", "notify"})

#: Parameter names under which the engine passes traced values.
TRACED_PARAMS = frozenset({
    "st", "state", "ctx", "est", "trace", "traces", "tr", "nom", "view",
    "aux", "carry", "xs", "key", "keys", "halted_state", "suffered",
    "action", "sysarr", "avail", "pending", "task", "tasks", "mask",
    "values", "val", "qstate", "t_now",
})

#: Call roots banned inside jit bodies (dotted-prefix match).
HOST_EFFECT_ROOTS = (
    "time.", "datetime.", "numpy.random.", "random.", "jax.debug.",
)
HOST_EFFECT_NAMES = frozenset({"print", "input", "open", "breakpoint"})

#: Field-annotation tokens that make a registry object unhashable.
UNHASHABLE_TOKENS = frozenset({
    "list", "List", "dict", "Dict", "set", "Set", "bytearray", "ndarray",
    "Array",
})


# --------------------------------------------------------------------------
# Parsing + shared per-file state
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParsedFile:
    path: str
    rel: str
    source: str
    tree: ast.AST
    allows: Dict[int, Dict[str, str]]   # line -> {marker-name: reason}
    jit_body_lines: Tuple[int, ...]     # lines carrying "# repro: jit-body"
    aliases: Dict[str, str]             # import alias -> dotted module


def _import_aliases(tree: ast.AST) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def parse_file(cfg: AnalysisConfig, path: str) -> ParsedFile:
    with open(path) as fh:
        source = fh.read()
    tree = ast.parse(source, filename=path)
    allows, jit_body = line_markers(source)
    return ParsedFile(
        path=path, rel=cfg.relpath(path).replace(os.sep, "/"),
        source=source, tree=tree, allows=allows,
        jit_body_lines=tuple(jit_body), aliases=_import_aliases(tree))


def parse_scope(cfg: AnalysisConfig,
                dirs: Sequence[str] = SCOPE_DIRS) -> List[ParsedFile]:
    return [parse_file(cfg, p) for p in cfg.python_files(*dirs)]


def _suppressed(pf: ParsedFile, lineno: int, marker: str,
                check: str, rule: str,
                out: List[Finding]) -> bool:
    """True if an ``allow-<marker>`` annotation covers ``lineno`` (same
    line or the line above). An empty ``[reason]`` still suppresses the
    original finding but emits an unexplained-suppression finding."""
    for ln in (lineno, lineno - 1):
        got = pf.allows.get(ln, {})
        if marker in got:
            if not got[marker]:
                out.append(Finding(
                    path=pf.rel, line=ln, rule=rule, check=check,
                    message=(f"allow-{marker} without a [reason] — "
                             "explain the suppression")))
            return True
    return False


def dotted_name(node: ast.AST,
                aliases: Optional[Dict[str, str]] = None) -> Optional[str]:
    """``jax.random.split`` for an Attribute/Name chain, alias-resolved."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = node.id
    if aliases and root in aliases:
        root = aliases[root]
    parts.append(root)
    return ".".join(reversed(parts))


def _jit_body_functions(pf: ParsedFile) -> List[ast.AST]:
    """Every function node the jit-body rules apply to (see module doc)."""
    marked = set(pf.jit_body_lines)
    out = []
    for node in ast.walk(pf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        name = node.name
        if (name.startswith("_stage_") or name in JIT_BODY_FUNCS
                or name in JIT_BODY_METHODS
                or node.lineno in marked or (node.lineno - 1) in marked):
            out.append(node)
    return out


def _body_without_nested(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested defs/lambdas
    (nested jit-body defs are visited in their own right)."""
    stack = list(getattr(fn, "body", []))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# --------------------------------------------------------------------------
# JD001 — registry objects must be frozen + hashable
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ClassInfo:
    rel: str
    lineno: int
    is_dataclass: bool
    frozen: bool
    is_protocol: bool
    is_namedtuple: bool
    fields: Tuple[Tuple[str, str, int], ...]  # (name, annotation, lineno)


def _class_info(node: ast.ClassDef, rel: str) -> _ClassInfo:
    is_dc = frozen = False
    for dec in node.decorator_list:
        call = dec if isinstance(dec, ast.Call) else None
        target = call.func if call else dec
        name = dotted_name(target) or ""
        if name.split(".")[-1] == "dataclass":
            is_dc = True
            if call:
                for kw in call.keywords:
                    if (kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)):
                        frozen = bool(kw.value.value)
    bases = {dotted_name(b) or "" for b in node.bases}
    base_tails = {b.split(".")[-1] for b in bases}
    fields = tuple(
        (stmt.target.id, ast.unparse(stmt.annotation), stmt.lineno)
        for stmt in node.body
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name))
    return _ClassInfo(
        rel=rel, lineno=node.lineno, is_dataclass=is_dc, frozen=frozen,
        is_protocol="Protocol" in base_tails,
        is_namedtuple="NamedTuple" in base_tails, fields=fields)


def _registered_class_names(pf: ParsedFile) -> Set[str]:
    """Class names reachable from ``register(...)`` calls in this file.

    Resolves the three idioms the repo uses: direct
    ``register("x", Ctor(...))``; module-level ``X = Ctor(...)`` then
    ``register("x", X)``; and the loop idiom ``for _n, _x in [("x",
    Ctor(...)), ...]: register(_n, _x)``. Constructor calls NESTED in a
    registered expression (``TwoPhasePolicy(MinEnergyFeasible(), ...)``)
    are collected too — component classes are fields of the cache key and
    must be just as hashable.
    """
    assigns: Dict[str, ast.expr] = {}
    for stmt in pf.tree.body if isinstance(pf.tree, ast.Module) else ():
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            assigns[stmt.targets[0].id] = stmt.value

    def classes_in(expr: ast.AST, depth: int = 0) -> Set[str]:
        found: Set[str] = set()
        if depth > 4:
            return found
        if isinstance(expr, ast.Name) and expr.id in assigns:
            return classes_in(assigns[expr.id], depth + 1)
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name and name[0].isupper():
                    found.add(name.split(".")[-1])
        return found

    loop_items: Dict[str, List[ast.expr]] = {}
    for node in ast.walk(pf.tree):
        if (isinstance(node, ast.For) and isinstance(node.target, ast.Tuple)
                and len(node.target.elts) == 2
                and isinstance(node.target.elts[1], ast.Name)
                and isinstance(node.iter, (ast.List, ast.Tuple))):
            item_var = node.target.elts[1].id
            loop_items[item_var] = [
                elt.elts[1] for elt in node.iter.elts
                if isinstance(elt, ast.Tuple) and len(elt.elts) == 2]

    out: Set[str] = set()
    for node in ast.walk(pf.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = (dotted_name(node.func) or "").split(".")[-1]
        if fname not in ("register", "register_fleet") or len(node.args) < 2:
            continue
        item = node.args[1]
        if isinstance(item, ast.Name) and item.id in loop_items:
            for expr in loop_items[item.id]:
                out |= classes_in(expr)
        else:
            out |= classes_in(item)
    return out


@dataclasses.dataclass(frozen=True)
class RegistryFrozenCheck:
    """JD001: registered objects are frozen dataclasses, hashable fields."""

    name: str = "registry-frozen"
    rule: str = "JD001"
    layer: int = 1
    dirs: Tuple[str, ...] = SCOPE_DIRS

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        files = parse_scope(cfg, self.dirs)
        index: Dict[str, _ClassInfo] = {}
        for pf in files:
            for node in ast.walk(pf.tree):
                if isinstance(node, ast.ClassDef):
                    index.setdefault(node.name, _class_info(node, pf.rel))
        registered: Set[str] = set()
        for pf in files:
            registered |= _registered_class_names(pf)

        out: List[Finding] = []
        by_rel = {pf.rel: pf for pf in files}
        for cname in sorted(registered):
            info = index.get(cname)
            if info is None or info.is_protocol:
                continue  # helper function / out-of-scope class
            pf = by_rel.get(info.rel)
            if info.is_namedtuple:
                continue  # immutable + hashable by construction
            if not (info.is_dataclass and info.frozen):
                if pf and _suppressed(pf, info.lineno, "registry",
                                      self.name, self.rule, out):
                    continue
                out.append(Finding(
                    path=info.rel, line=info.lineno, rule=self.rule,
                    check=self.name,
                    message=(f"registered class {cname} must be a "
                             "@dataclass(frozen=True) — registry objects "
                             "are jit static-arg cache keys")))
                continue
            for fname, ann, lineno in info.fields:
                tokens = set(
                    t for t in
                    ann.replace("[", " ").replace("]", " ")
                       .replace(".", " ").replace(",", " ").split())
                bad = tokens & UNHASHABLE_TOKENS
                if bad:
                    if pf and _suppressed(pf, lineno, "registry",
                                          self.name, self.rule, out):
                        continue
                    out.append(Finding(
                        path=info.rel, line=lineno, rule=self.rule,
                        check=self.name,
                        message=(f"{cname}.{fname}: unhashable field type "
                                 f"{ann!r} ({sorted(bad)[0]}) breaks the "
                                 "registry object's use as a jit cache "
                                 "key")))
        return out


# --------------------------------------------------------------------------
# JD002 — CRN discipline: PRNGKey/split only in sanctioned helpers
# --------------------------------------------------------------------------

#: Modules allowed to mint/split key material (repo-relative prefixes).
CRN_SANCTIONED = (
    "src/repro/datapipe/synthetic.py",
    "src/repro/core/faults/base.py",     # hash_uniform counter PRNG
)

_PRNG_CALLS = frozenset({"jax.random.PRNGKey", "jax.random.split",
                         "jax.random.key", "jax.random.fold_in"})


@dataclasses.dataclass(frozen=True)
class CrnDisciplineCheck:
    """JD002: PRNGKey/split only in sanctioned CRN helpers (or marked)."""

    name: str = "crn-discipline"
    rule: str = "JD002"
    layer: int = 1
    dirs: Tuple[str, ...] = SCOPE_DIRS

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        out: List[Finding] = []
        for pf in parse_scope(cfg, self.dirs):
            if any(pf.rel.startswith(p) for p in CRN_SANCTIONED):
                continue
            for node in ast.walk(pf.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func, pf.aliases)
                if name not in _PRNG_CALLS:
                    continue
                if _suppressed(pf, node.lineno, "prng", self.name,
                               self.rule, out):
                    continue
                out.append(Finding(
                    path=pf.rel, line=node.lineno, rule=self.rule,
                    check=self.name,
                    message=(f"{name} outside sanctioned CRN helpers — "
                             "ad-hoc key material breaks common-random-"
                             "number pairing across policies; derive keys "
                             "in datapipe.synthetic or use "
                             "faults.hash_uniform")))
        return out


# --------------------------------------------------------------------------
# JD003 — no host effects in jit bodies
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HostEffectsCheck:
    """JD003: no time/np.random/print/datetime calls in jit bodies."""

    name: str = "host-effects"
    rule: str = "JD003"
    layer: int = 1
    dirs: Tuple[str, ...] = SCOPE_DIRS

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        out: List[Finding] = []
        for pf in parse_scope(cfg, self.dirs):
            if pf.rel.endswith("core/pyengine.py"):
                continue  # the oracle is host-side by design
            for fn in _jit_body_functions(pf):
                for node in _body_without_nested(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    name = dotted_name(node.func, pf.aliases) or ""
                    banned = (name in HOST_EFFECT_NAMES or any(
                        name.startswith(root) for root in
                        HOST_EFFECT_ROOTS))
                    if not banned:
                        continue
                    if _suppressed(pf, node.lineno, "host", self.name,
                                   self.rule, out):
                        continue
                    out.append(Finding(
                        path=pf.rel, line=node.lineno, rule=self.rule,
                        check=self.name,
                        message=(f"host-side effect {name}() inside jit "
                                 f"body {fn.name}() — runs at trace time "
                                 "only (or crashes), never per step")))
        return out


# --------------------------------------------------------------------------
# JD004 — no Python branches on traced values in jit bodies
# --------------------------------------------------------------------------

_LAUNDER_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "aval"})
_TAINT_CALL_PREFIXES = ("jnp.", "jax.", "lax.", "jax.numpy.", "jax.lax.")


class _TaintVisitor:
    """Forward taint pass over one function body.

    Names bound from traced roots (or from jnp/lax call results) are
    tainted; ``.shape``-style attribute access, ``len()``, and
    ``is``/``is not`` comparisons launder. Run statements in source
    order; good enough for the straight-line jnp code jit bodies are
    (that being the point of the rule).
    """

    def __init__(self, fn: ast.AST, aliases: Dict[str, str]):
        self.aliases = aliases
        self.tainted: Set[str] = set()
        args = fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            if a.arg in TRACED_PARAMS:
                self.tainted.add(a.arg)

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _LAUNDER_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            ops = node.ops
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in ops):
                return False  # `x is None` is a static structure test
            return (self.is_tainted(node.left)
                    or any(self.is_tainted(c) for c in node.comparators))
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func, self.aliases) or ""
            if fname == "len" or fname.endswith(".shape"):
                return False
            if any(fname.startswith(p) for p in _TAINT_CALL_PREFIXES):
                return True
            if isinstance(node.func, ast.Attribute):  # x.astype(...), x.sum()
                return self.is_tainted(node.func.value)
            return any(self.is_tainted(a) for a in node.args)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        return False

    def bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if tainted
             else self.tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self.bind(e, tainted)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, tainted)


@dataclasses.dataclass(frozen=True)
class TracedBranchCheck:
    """JD004: no Python if/while/bool()/int() on traced values in jit."""

    name: str = "traced-branch"
    rule: str = "JD004"
    layer: int = 1
    dirs: Tuple[str, ...] = SCOPE_DIRS

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        out: List[Finding] = []
        for pf in parse_scope(cfg, self.dirs):
            if pf.rel.endswith("core/pyengine.py"):
                continue  # host-side oracle: Python control flow is its job
            for fn in _jit_body_functions(pf):
                self._scan_function(pf, fn, out)
        return out

    def _scan_function(self, pf: ParsedFile, fn: ast.AST,
                       out: List[Finding]) -> None:
        tv = _TaintVisitor(fn, pf.aliases)

        def emit(node: ast.AST, what: str) -> None:
            if _suppressed(pf, node.lineno, "branch", self.name,
                           self.rule, out):
                return
            out.append(Finding(
                path=pf.rel, line=node.lineno, rule=self.rule,
                check=self.name,
                message=(f"{what} on a traced value in jit body "
                         f"{fn.name}() — use lax.cond/jnp.where; Python "
                         "control flow is resolved once at trace time")))

        def visit_stmts(stmts: Sequence[ast.stmt]) -> None:
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue  # nested defs scanned in their own right
                if isinstance(stmt, ast.Assign):
                    t = tv.is_tainted(stmt.value)
                    for tgt in stmt.targets:
                        tv.bind(tgt, t)
                elif isinstance(stmt, ast.AugAssign):
                    if tv.is_tainted(stmt.value):
                        tv.bind(stmt.target, True)
                elif isinstance(stmt, ast.AnnAssign) and stmt.value:
                    tv.bind(stmt.target, tv.is_tainted(stmt.value))
                elif isinstance(stmt, ast.If):
                    if tv.is_tainted(stmt.test):
                        emit(stmt, "Python `if`")
                    visit_stmts(stmt.body)
                    visit_stmts(stmt.orelse)
                    continue
                elif isinstance(stmt, ast.While):
                    if tv.is_tainted(stmt.test):
                        emit(stmt, "Python `while`")
                    visit_stmts(stmt.body)
                    visit_stmts(stmt.orelse)
                    continue
                elif isinstance(stmt, ast.Assert):
                    if tv.is_tainted(stmt.test):
                        emit(stmt, "`assert`")
                for node in ast.walk(stmt):
                    if isinstance(node, ast.IfExp) and tv.is_tainted(
                            node.test):
                        emit(node, "conditional expression")
                    elif (isinstance(node, ast.Call)
                          and isinstance(node.func, ast.Name)
                          and node.func.id in ("bool", "int")
                          and node.args
                          and tv.is_tainted(node.args[0])):
                        emit(node, f"`{node.func.id}()` coercion")
                if isinstance(stmt, (ast.For, ast.With, ast.Try)):
                    for body in (getattr(stmt, "body", []),
                                 getattr(stmt, "orelse", []),
                                 getattr(stmt, "finalbody", [])):
                        visit_stmts(body)

        visit_stmts(getattr(fn, "body", []))


# --------------------------------------------------------------------------
# JD005 — pyengine oracle arithmetic stays np.float32
# --------------------------------------------------------------------------

#: Helper-name patterns whose bodies mirror engine decision arithmetic.
_ORACLE_HELPER_PREFIXES = ("_nominate", "_key_", "avail", "phase2")
_ORACLE_HELPER_NAMES = frozenset({"qsum", "suffered_mask",
                                  "_refresh_tables"})
_F32_WRAPPERS = frozenset({"F", "np.float32", "numpy.float32"})


@dataclasses.dataclass(frozen=True)
class OracleF32Check:
    """JD005: pyengine decision arithmetic stays in np.float32."""

    name: str = "oracle-f32"
    rule: str = "JD005"
    layer: int = 1
    oracle_rel: str = "src/repro/core/pyengine.py"

    def run(self, cfg: AnalysisConfig) -> List[Finding]:
        path = os.path.join(cfg.root, self.oracle_rel)
        if not os.path.exists(path):
            return [Finding(
                path=self.oracle_rel, line=0, rule=self.rule,
                check=self.name, message="pyengine oracle not found")]
        pf = parse_file(cfg, path)
        out: List[Finding] = []
        for fn in ast.walk(pf.tree):
            is_lambda = isinstance(fn, ast.Lambda)
            if not (is_lambda or isinstance(fn, ast.FunctionDef)):
                continue
            if not is_lambda and not self._is_decision_helper(fn.name):
                continue
            body = [fn.body] if is_lambda else fn.body
            label = "<lambda>" if is_lambda else fn.name + "()"
            for stmt in body:
                self._scan(pf, stmt, label, out)
        return out

    @staticmethod
    def _is_decision_helper(name: str) -> bool:
        return (name in _ORACLE_HELPER_NAMES
                or any(name.startswith(p)
                       for p in _ORACLE_HELPER_PREFIXES))

    def _scan(self, pf: ParsedFile, root: ast.AST, label: str,
              out: List[Finding]) -> None:
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(root):
            for child in ast.iter_child_nodes(node):
                parents[child] = node

        def emit(node: ast.AST, msg: str) -> None:
            if _suppressed(pf, node.lineno, "oracle-f32", self.name,
                           self.rule, out):
                return
            out.append(Finding(path=pf.rel, line=node.lineno,
                               rule=self.rule, check=self.name,
                               message=f"{msg} in oracle helper {label}"))

        for node in ast.walk(root):
            name = dotted_name(node, pf.aliases) if isinstance(
                node, (ast.Attribute, ast.Name)) else None
            if name in ("np.float64", "numpy.float64", "np.double",
                        "numpy.double"):
                emit(node, "np.float64 reference — mirrored decision "
                           "arithmetic must stay np.float32")
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                tgt = dotted_name(node.args[0], pf.aliases) or (
                    node.args[0].id if isinstance(node.args[0], ast.Name)
                    else "")
                if tgt in ("float", "np.float64", "numpy.float64"):
                    emit(node, f"astype({tgt}) upcast")
            if (isinstance(node, ast.Constant)
                    and isinstance(node.value, float)):
                parent = parents.get(node)
                if isinstance(parent, ast.BinOp):
                    emit(node, f"bare float literal {node.value!r} in "
                               "arithmetic — wrap in F(...) so the "
                               "operation stays float32")
                elif (isinstance(parent, ast.Call)
                      and (dotted_name(parent.func, pf.aliases) or "")
                      not in _F32_WRAPPERS
                      and not isinstance(parents.get(parent),
                                         (ast.Call,))):
                    pass  # float args to non-arithmetic calls are fine
        return None


# --------------------------------------------------------------------------
# Registration — the registry idiom, applied to the analyzer itself.
# --------------------------------------------------------------------------

for _name, _check in [
    ("registry-frozen", RegistryFrozenCheck()),
    ("crn-discipline", CrnDisciplineCheck()),
    ("host-effects", HostEffectsCheck()),
    ("traced-branch", TracedBranchCheck()),
    ("oracle-f32", OracleF32Check()),
]:
    _registry.register(_name, _check)
del _name, _check
