"""The analyzer CLI: ``python -m repro.analysis.check``.

Exit status is the contract — 0 means every selected check ran and
found nothing; 1 means findings (printed one per line as
``path:line: RULE [check] message``) or a crashed check. ``--json OUT``
writes the structured report CI uploads as an artifact.

    python -m repro.analysis.check                   # full suite
    python -m repro.analysis.check --list-checks
    python -m repro.analysis.check --layer 1         # AST only (no JAX)
    python -m repro.analysis.check --checks crn-discipline,host-effects
    python -m repro.analysis.check --json analysis.json
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis import (
    CHECKS,
    find_repo_root,
    format_findings,
    report_dict,
    run_checks,
)
from repro.analysis.findings import write_json


def _parse_args(argv: Optional[List[str]] = None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="jit-discipline static analyzer (AST lint + jaxpr audit)")
    ap.add_argument("--list-checks", action="store_true",
                    help="list registered checks and exit")
    ap.add_argument("--checks", default=None, metavar="NAME[,NAME...]",
                    help="run only these checks (default: all)")
    ap.add_argument("--layer", type=int, choices=(1, 2), default=None,
                    help="run only one layer (1=AST lint, 2=jaxpr audit)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the structured JSON report to OUT")
    ap.add_argument("--root", default=None,
                    help="repo root (default: walk up to pyproject.toml)")
    return ap.parse_args(argv)


def main(argv: Optional[List[str]] = None) -> int:
    args = _parse_args(argv)
    if args.list_checks:
        for name in CHECKS.names():
            c = CHECKS.get(name)
            doc = (type(c).__doc__ or "").strip().splitlines()
            head = doc[0] if doc else ""
            print(f"{name:16s} {c.rule}  L{c.layer}  {head}")
        return 0

    selected = (args.checks.split(",") if args.checks
                else list(CHECKS.names()))
    layers = (args.layer,) if args.layer else (1, 2)
    findings, errors = run_checks(selected, root=args.root, layers=layers)
    ran = [n for n in selected if CHECKS.get(n).layer in layers]

    if findings:
        print(format_findings(findings))
    for err in errors:
        print(f"ERROR: check crashed: {err}", file=sys.stderr)

    root = args.root or find_repo_root()
    report = report_dict(findings, checks=ran, root=root, errors=errors)
    if args.json:
        write_json(args.json, report)
    n, e = len(findings), len(errors)
    status = "clean" if report["ok"] else (
        f"{n} finding(s)" + (f", {e} crashed check(s)" if e else ""))
    print(f"repro.analysis: {len(ran)} check(s) -> {status}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
