"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links. We
compress per-tensor to int8 with a power-of-two-free dynamic scale and keep
the quantization residual locally (error feedback), which preserves
convergence (Karimireddy et al. 2019 style). Intra-pod reduction stays fp32.

Under jit the compression simply rewrites the gradient pytree around the
``psum``; XLA then moves 4x fewer bytes across the ``pod`` axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    """-> (quantized tree, scales tree, new residuals)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        new_r = g32 - dequantize_int8(q, s)
        return (q, s, new_r)

    flat = jax.tree.map(one, grads, residuals,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    qs = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    ss = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    rs = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return qs, ss, rs


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def crosspod_mean_compressed(grads, residuals, axis_name: str):
    """Error-feedback int8 mean over ``axis_name`` (inside shard_map/pmap)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        # shared scale = pmax of local dynamic ranges, so every shard's int8
        # payload dequantizes exactly (one tiny fp32 all-reduce for scales)
        local_s = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        s = jax.lax.pmax(local_s, axis_name)
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        new_r = g32 - q.astype(jnp.float32) * s
        # int8 payload summed in int32 across pods (4x fewer link bytes)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * s / n).astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residuals)
    g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return g, r
