"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The layer stack is split into ``P`` stages along a ``pipe`` mesh axis; M
microbatches stream through with the classic (M + P - 1)-tick schedule.
Stage-to-stage activation handoff is a ``collective_permute`` ring shift —
the jax-native mapping of the paper-adjacent send/recv pattern (DESIGN.md §5:
PP is a supported feature, validated at small scale; the headline dry-run
mesh uses DP x TP where PP is not needed for the assigned cells).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn, mesh, axis: str = "pipe"):
    """Build a pipelined apply.

    stage_fn(stage_params, x) -> x', the per-stage transform (e.g. a scan
    over the stage's layers). stage_params leaves have a leading dim == P
    (stage-major stacking); x: (M, ...) microbatches.

    Returns run(stacked_params, x_microbatches) -> (M, ...) outputs,
    numerically identical to applying all stages sequentially.
    """
    n_stages = mesh.shape[axis]

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axis), P()),     # params sharded by stage; x replicated
        out_specs=P(),
    )
    def run(stage_params, xs):
        # inside: stage_params leaves have leading dim 1 (this stage)
        local = jax.tree.map(lambda a: a[0], stage_params)
        sid = jax.lax.axis_index(axis)
        M = xs.shape[0]
        n_ticks = M + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(t, state):
            recv, outs = state
            # stage 0 ingests microbatch t (if any); others take the ring
            mb = jnp.where(t < M, t, 0)
            x_in = jnp.where(sid == 0, xs[mb], recv)
            y = stage_fn(local, x_in)
            # last stage emits microbatch t - (P - 1)
            out_idx = t - (n_stages - 1)
            emit = (sid == n_stages - 1) & (out_idx >= 0)
            oi = jnp.clip(out_idx, 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, oi, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, cur), oi, 0)
            recv = jax.lax.ppermute(y, axis, perm)
            return recv, outs

        # initial carries must be typed as pipe-varying for the fori_loop
        outs0 = jax.lax.pcast(jnp.zeros_like(xs), (axis,), to="varying")
        recv0 = jax.lax.pcast(jnp.zeros_like(xs[0]), (axis,), to="varying")
        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (recv0, outs0))
        # only the last stage holds real outputs; share them back to all
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run


def stack_stages(layer_params, n_stages: int):
    """(L, ...) layer-stacked params -> (P, L/P, ...) stage-major stacking."""
    def resh(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages}"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(resh, layer_params)
