"""Ring attention: sequence-parallel exact attention via shard_map.

For long-context prefill the (B, S, H, hd) activations are sharded over the
sequence on a mesh axis; K/V shards rotate around the ring with
``ppermute`` while each device accumulates its queries' online softmax —
exact attention with S/P-sized working sets and the comm hidden behind the
next block's compute (the TPU-native analogue of RingAttention /
context parallelism; DESIGN.md §5 SP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ring_attention(q, k, v, mesh, axis: str = "model", *, causal=True):
    """q, k, v: (B, S, H, hd) with S divisible by mesh.shape[axis].

    Returns (B, S, H, hd), numerically equal to full softmax attention.
    GQA: pass k/v already head-repeated (or Hkv == H).
    """
    n = mesh.shape[axis]

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
    )
    def run(ql, kl, vl):
        i = jax.lax.axis_index(axis)
        B, Sl, H, hd = ql.shape
        scale = hd ** -0.5
        qf = ql.astype(jnp.float32) * scale
        q_pos = i * Sl + jnp.arange(Sl)

        def step(r, carry):
            kr, vr, m, l, acc = carry
            # kr currently holds the shard that started at ring slot (i - r)
            src = (i - r) % n
            k_pos = src * Sl + jnp.arange(Sl)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr.astype(jnp.float32))
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vr.astype(jnp.float32))
            perm = [(j, (j + 1) % n) for j in range(n)]
            kr = jax.lax.ppermute(kr, axis, perm)
            vr = jax.lax.ppermute(vr, axis, perm)
            return kr, vr, m_new, l_new, acc

        m0 = jnp.full((B, H, Sl), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, Sl), jnp.float32)
        a0 = jnp.zeros((B, H, Sl, hd), jnp.float32)
        m0, l0, a0 = (jax.lax.pcast(x, (axis,), to="varying")
                      for x in (m0, l0, a0))
        _, _, m, l, acc = jax.lax.fori_loop(
            0, n, step, (kl, vl, m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.swapaxes(1, 2).astype(ql.dtype)

    return run(q, k, v)
