"""Sharding rules: parameter / batch / cache PartitionSpecs + sweep grids.

Two consumers share this module:

  * the training/serving stack (parameter, batch and decode-cache
    PartitionSpecs below), and
  * the scheduling lab's Monte-Carlo sweeps: :func:`sweep_mesh` /
    :func:`pad_batch` back ``experiments.run_sweep(..., shard=True)``,
    which splits the flattened (rate x replicate) trace batch across
    every visible device with ``jax.shard_map`` — each device simulates
    its slice of the CRN grid, results are bit-identical to the
    unsharded path because traces are independent (pinned in
    ``tests/test_distributed.py``).

Layout (DESIGN.md §5):
  * params: FSDP over ``data`` (one matmul dim), TP over ``model`` (heads /
    ffn-inner / vocab), replicated over ``pod`` — gradients are all-reduced
    across pods (the compressible cross-pod collective).
  * batch: sharded over (``pod``, ``data``).
  * decode caches: batch over (``pod``, ``data``); KV heads over ``model``
    when divisible, otherwise KV *sequence* over ``model`` (GQA archs whose
    kv-head count is below the TP width — sequence-parallel decode).
  * MoE experts: EP over ``model``.
Scanned layer stacks carry one leading (layer) dim, never sharded.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name -> spec for the *trailing* dims (scan dims padded with None).
# (data, model) = (FSDP, TP).
_NAME_RULES: dict[str, tuple] = {
    "tok": ("model", "data"),        # (V, d): vocab TP'd for the LM head
    "unembed": ("data", "model"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "router": ("data", None),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "w_in": ("data", "model"),
    "w_out": ("model", "data"),
    "w_if": ("data", None),
    "b_up": ("model",),
}


def _moe_aware(name: str, ndim: int):
    """w_gate/w_up/w_down appear in both dense MLP (2D) and MoE (3D)."""
    if name in ("w_gate", "w_up"):
        return ("model", "data", None) if ndim == 3 else ("data", "model")
    if name == "w_down":
        return ("model", None, "data") if ndim == 3 else ("model", "data")
    return None


def _path_names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def param_spec(path, leaf) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    scan = 1 if any(n in ("blocks", "enc_blocks") for n in names) else 0
    ndim = leaf.ndim - scan
    rule = _moe_aware(name, ndim)
    if rule is None:
        rule = _NAME_RULES.get(name)
    if rule is None or len(rule) != ndim:
        rule = (None,) * ndim  # replicate (norms, convs, scalars, gates)
    return P(*((None,) * scan + tuple(rule)))


def param_specs(params_shapes):
    return jax.tree_util.tree_map_with_path(param_spec, params_shapes)


def _valid(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries that don't divide the dim (safety net)."""
    fixed = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            fixed.append(None)
            continue
        ax = (names,) if isinstance(names, str) else tuple(names)
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        fixed.append(names if dim % size == 0 else None)
    return P(*fixed)


def _attn_overrides(cfg, mesh: Mesh) -> dict:
    """Head-divisibility-aware TP for attention projections.

    Sharding the fused head dim when (n_heads % tp != 0) makes the
    (B,S,H*hd) -> (B,S,H,hd) reshape inexpressible and the partitioner
    inserts per-layer activation reshard all-reduces (§Perf iteration B:
    10 TB/step on internvl2's 14-head/2-kv stack at TP=16). Projections
    whose head count doesn't divide the TP width fall back to FSDP-only.
    """
    tp = mesh.shape.get("model", 1)
    if cfg is None or tp == 1:
        return {}
    over = {}
    if cfg.n_heads % tp:
        over.update({"wq": ("data", None), "wo": (None, "data"),
                     "bq": (None,)})
    if cfg.n_kv_heads % tp:
        over.update({"wk": ("data", None), "wv": ("data", None),
                     "bk": (None,), "bv": (None,)})
    return over


def param_shardings(params_shapes, mesh: Mesh, cfg=None):
    over = _attn_overrides(cfg, mesh)

    def one(path, leaf):
        spec = param_spec(path, leaf)
        names = _path_names(path)
        name = names[-1] if names else ""
        if name in over:
            scan = 1 if any(n in ("blocks", "enc_blocks") for n in names) \
                else 0
            spec = P(*((None,) * scan + tuple(over[name])))
        return NamedSharding(mesh, _valid(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_state_shardings(params_shapes, mesh: Mesh, cfg=None):
    """Adam mu/nu mirror the param layout; step is replicated."""
    from repro.optim.adamw import AdamWState

    pspecs = param_shardings(params_shapes, mesh, cfg)
    rep = NamedSharding(mesh, P())
    return AdamWState(step=rep, mu=pspecs, nu=pspecs)


def batch_axes(mesh: Mesh):
    """The data-parallel mesh axes (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh: Mesh, batch_shapes, accum_dim: bool = False):
    dp = batch_axes(mesh)

    def one(leaf):
        shape = leaf.shape
        b_idx = 1 if accum_dim else 0
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        spec = [None] * len(shape)
        if shape[b_idx] % dp_size == 0 and dp_size > 1:
            spec[b_idx] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shapes)


def cache_sharding(cfg, mesh: Mesh, cache_shapes):
    """Decode-cache shardings: batch over DP axes; heads-or-seq over model."""
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        spec = [None] * len(shape)
        if name in ("len", "xlen"):
            return NamedSharding(mesh, P(*spec))
        # leading layer-stack dim then batch
        b_idx = 1 if len(shape) >= 2 else 0
        if shape[b_idx] % dp_size == 0 and dp_size > 1:
            spec[b_idx] = dp
        if name in ("k", "v", "xk", "xv") and len(shape) == 5:
            # (L, B, S, Hkv, hd): heads over model if divisible, else seq
            if shape[3] % tp == 0:
                spec[3] = "model"
            elif shape[2] % tp == 0:
                spec[2] = "model"
        elif name == "ssm" and len(shape) == 5:
            # (L, B, H, N, P): ssm heads over model
            if shape[2] % tp == 0:
                spec[2] = "model"
        elif name == "conv" and len(shape) == 4:
            if shape[3] % tp == 0:
                spec[3] = "model"
        elif name in ("S", "n", "c", "h", "m") and len(shape) >= 4:
            # xlstm states (nsb, B, H, ...): shard widest trailing dim
            for d in range(len(shape) - 1, 1, -1):
                if shape[d] % tp == 0 and shape[d] >= tp:
                    spec[d] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Sweep-grid sharding: the (policy x rate x replicate) Monte-Carlo batch
# --------------------------------------------------------------------------

#: Mesh axis name the sweep batch is sharded over.
SWEEP_AXIS = "grid"


def sweep_mesh(max_devices: int | None = None):
    """A 1-D device mesh over the sweep batch axis, or ``None``.

    Returns ``None`` when only one device is visible (or ``max_devices``
    caps it to one) — the caller falls back to the plain single-device
    path, so ``shard=True`` is always safe to request.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if max_devices is None else min(int(max_devices),
                                                  len(devs))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), (SWEEP_AXIS,))


def pad_batch(tree, multiple: int):
    """Pad every leaf's leading batch dim up to a multiple of ``multiple``.

    Padding rows repeat row 0 (a real, finite trace — the simulator runs
    it and the caller slices the padding back off), so sharding never
    requires the batch to divide the device count.
    """
    import jax.numpy as jnp

    def one(x):
        pad = (-x.shape[0]) % multiple
        if pad == 0:
            return x
        fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
        return jnp.concatenate([x, fill], axis=0)

    return jax.tree.map(one, tree)
