"""Training launcher: --arch <id> on a host mesh (or the production mesh on
real hardware), with checkpoints and restart.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --smoke            # reduced config, CPU
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --production-mesh             # on a real pod: full config + mesh
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.datapipe.synthetic import Prefetcher, SyntheticLM
from repro.launch import mesh as mesh_mod
from repro.models import transformer as tf
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP width for the host mesh")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    mesh = (mesh_mod.make_production_mesh() if args.production_mesh
            else mesh_mod.make_host_mesh(args.model_axis))
    single = mesh.devices.size == 1

    opt = AdamW(lr=None)
    sched = cosine_with_warmup(args.lr, warmup=min(100, args.steps // 10 + 1),
                               total=args.steps)
    step_fn = make_train_step(cfg, opt, None if single else mesh,
                              lr_schedule=sched, donate=False)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, accum=args.accum)

    params = tf.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    start = 0
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        state, start = ckpt.restore(
            args.ckpt, {"p": tf.param_shapes(cfg),
                        "o": jax.eval_shape(opt.init, tf.param_shapes(cfg))})
        params, opt_state = state["p"], state["o"]
        print(f"restored from step {start}")

    if not single:
        b0 = data.batch_at(0)
        with mesh:
            step_fn = step_fn.jit_for(jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), b0))

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={mesh.devices.size} batch={args.batch} seq={args.seq}")

    it = iter(Prefetcher(data))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(it)
        if single:
            params, opt_state, m = step_fn(params, opt_state, batch)
        else:
            with mesh:
                params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            tput = args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} tok/s {tput:.0f}")
            t0 = time.time()
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, step + 1, {"p": params, "o": opt_state},
                      blocking=False)
    if args.ckpt:
        ckpt.save(args.ckpt, args.steps, {"p": params, "o": opt_state})


if __name__ == "__main__":
    main()
