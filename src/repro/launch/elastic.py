"""Elastic-rescale demo: train on mesh A, checkpoint, resume on mesh B.

Runs with placeholder devices so the rescale story is visible on one host:

  PYTHONPATH=src python -m repro.launch.elastic --devices 8 \
      --mesh-a 4,2 --mesh-b 2,4 --steps 20

The checkpoint layout is mesh-agnostic (host-gathered leaves); restore uses
``jax.make_array_from_callback`` against the new mesh's shardings — the same
machinery a fleet uses when a pod is added or lost between incarnations.
"""
import argparse
import os
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh-a", default="4,2")
    ap.add_argument("--mesh-b", default="2,4")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.checkpoint import ckpt
    from repro.configs import registry
    from repro.datapipe.synthetic import SyntheticLM
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh
    from repro.models import transformer as tf
    from repro.optim.adamw import AdamW
    from repro.train.steps import make_train_step

    cfg = registry.get_smoke_config("internlm2-1.8b")
    opt = AdamW(lr=1e-3)
    data = SyntheticLM(cfg, batch=8, seq=32, accum=2)

    def run_phase(mesh_shape, start, stop, ckpt_dir):
        mesh = make_mesh(tuple(int(x) for x in mesh_shape.split(",")),
                         ("data", "model"))
        pshapes = tf.param_shapes(cfg)
        oshapes = jax.eval_shape(opt.init, pshapes)
        pshard = sh.param_shardings(pshapes, mesh, cfg)
        oshard = sh.opt_state_shardings(pshapes, mesh, cfg)
        if ckpt.latest_step(ckpt_dir) is None:
            params = tf.init(jax.random.PRNGKey(0), cfg)
            opt_state = opt.init(params)
        else:
            state, at = ckpt.restore(
                ckpt_dir, {"p": pshapes, "o": oshapes},
                shardings={"p": pshard, "o": oshard})
            params, opt_state = state["p"], state["o"]
            print(f"  restored step {at} onto mesh {mesh.shape}")
        step_fn = make_train_step(cfg, opt, mesh, donate=False)
        b0 = data.batch_at(start)
        with mesh:
            jitted = step_fn.jit_for(jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), b0))
            for s in range(start, stop):
                params, opt_state, m = jitted(params, opt_state,
                                              data.batch_at(s))
        print(f"  mesh {mesh.shape}: steps {start}..{stop - 1}, "
              f"final loss {float(m['loss']):.4f}")
        ckpt.save(ckpt_dir, stop, {"p": params, "o": opt_state})
        return params

    with tempfile.TemporaryDirectory() as d:
        half = args.steps // 2
        print(f"phase 1 on mesh ({args.mesh_a}):")
        run_phase(args.mesh_a, 0, half, d)
        print(f"phase 2 on mesh ({args.mesh_b}) — elastic rescale:")
        p_b = run_phase(args.mesh_b, half, args.steps, d)

    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(p_b))
    print(f"done: {args.steps} steps across two mesh shapes "
          f"({n/1e6:.1f}M params); checkpoints were mesh-agnostic.")


if __name__ == "__main__":
    main()
