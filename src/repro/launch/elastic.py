"""Elastic federation demo: sites leave and rejoin the fleet mid-trace.

Drives the engine's faults subsystem (:mod:`repro.core.faults`) as an
*elasticity* mechanism: a :class:`~repro.core.faults.SiteOutage` window
per departing site models planned downtime (maintenance, spot
reclamation), the ``health_aware`` dispatcher re-homes admissions onto
the remaining sites via the site-health mask, and the ``health``
observer reports the capacity timeline the fleet actually delivered —
the same machinery that absorbs an *unplanned* outage, pointed at
planned rescale events.

  PYTHONPATH=src python -m repro.launch.elastic \
      --fleet paper_x4 --tasks 400 --rate 6 --down 1:0.25:0.5,2:0.5:0.75

``--down site:start:end`` windows are horizon fractions; the default
takes one site out for the middle half of the trace.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import scenarios
from repro.core import engine, faults, workload


def _parse_down(text: str):
    """``site:start:end`` comma list -> SiteOutage windows."""
    out = []
    for part in text.split(","):
        if not part.strip():
            continue
        s, a, b = part.split(":")
        out.append((int(s), float(a), float(b)))
    return tuple(out)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.elastic",
        description="Elastic federation: scheduled site departures, "
                    "health-masked dispatch, capacity timeline.",
    )
    ap.add_argument("--fleet", default="paper_x4",
                    help="registered fleet builder (default: paper_x4)")
    ap.add_argument("--tasks", type=int, default=400)
    ap.add_argument("--rate", type=float, default=6.0,
                    help="arrival rate, tasks/sec (default: 6)")
    ap.add_argument("--heuristic", default="FELARE")
    ap.add_argument("--down", default="1:0.25:0.75",
                    help="comma list of site:start:end departure windows "
                         "(horizon fractions; default: 1:0.25:0.75)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = scenarios.get_fleet(args.fleet).build()
    trace = workload.poisson_trace(
        jax.random.PRNGKey(args.seed), n_tasks=args.tasks,
        arrival_rate=args.rate, eet=spec.eet,
    )
    outage = faults.SiteOutage(outages=_parse_down(args.down))
    m, aux = engine.simulate(
        trace, spec, heuristic=args.heuristic, dispatcher="health_aware",
        dynamics=outage, observers=("health",),
    )
    health = jax.tree.map(np.asarray, aux["health"])

    done = float(m.completed_by_type.sum())
    arrived = float(m.arrived_by_type.sum())
    ontime = done / max(arrived, 1.0)
    fleet_size = int(health["healthy"].max())
    print(f"elastic fleet {args.fleet}: {args.tasks} tasks @ "
          f"{args.rate:g}/s, departures {args.down}")
    print(f"on-time {100 * ontime:.1f}%  orphan re-dispatches "
          f"{int(health['orphans'][-1])}")
    print("\ncapacity timeline (healthy machines per bucket):")
    K = len(health["healthy"])
    for b in range(0, K, max(1, K // 16)):
        bar = "#" * int(health["healthy"][b])
        live = int(health["site_alive"][b].sum())
        print(f"  t={health['t'][b]:7.2f}  {bar:{fleet_size}s} "
              f"{int(health['healthy'][b]):3d} machines, {live} sites live")
    return {
        "ontime": ontime,
        "orphans": int(health["orphans"][-1]),
        "healthy": health["healthy"],
        "site_alive": health["site_alive"],
        "min_sites_live": int(health["site_alive"].sum(axis=1).min()),
    }


if __name__ == "__main__":
    main()
