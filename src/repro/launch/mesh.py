"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run launcher sets XLA_FLAGS before any jax import;
smoke tests and benches see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips (data, model).
    Multi-pod: (2, 16, 16) = 512 chips (pod, data, model) — the ``pod`` axis
    carries cross-pod data parallelism (gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, elastic rescale)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model: int = 1):
    """Whatever this host has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
