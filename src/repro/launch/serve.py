"""Serving launcher: the FELARE-routed heterogeneous serving runtime.

  PYTHONPATH=src python -m repro.launch.serve --requests 200 \
      --heuristic FELARE --archs qwen1.5-0.5b internlm2-1.8b

Machines come from repro.cluster.profiles.FLEET; the EET matrix is seeded
from the roofline model of each (arch x machine) and refined online. This is
the production entry point that examples/serve_edge.py demonstrates at
miniature scale with real model execution.
"""
from __future__ import annotations

import argparse
import heapq

import numpy as np

from repro.cluster import profiles
from repro.cluster.router import Request, Router
from repro.configs import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["qwen1.5-0.5b", "internlm2-1.8b",
                             "whisper-medium", "xlstm-125m"])
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--tokens", type=int, default=256)
    ap.add_argument("--heuristic", default="FELARE")
    ap.add_argument("--queue-size", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfgs = [registry.get_config(a) for a in args.archs]
    eet = profiles.eet_from_roofline(cfgs, n_tokens=args.tokens)
    p_dyn, p_idle = profiles.power_vectors()
    mean_e = eet.mean(axis=1)
    slack = mean_e + mean_e.mean()

    class Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = Clock()
    router = Router(eet, p_dyn, p_idle, queue_size=args.queue_size,
                    heuristic=args.heuristic, now_fn=clock)

    rng = np.random.default_rng(args.seed)
    events = []
    t = 0.0
    for rid in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        tt = int(rng.integers(0, len(cfgs)))
        heapq.heappush(events, (t, 0, rid, tt))

    while events:
        tm, kind, a, b = heapq.heappop(events)
        clock.t = tm
        if kind == 0:
            started = router.on_request(Request(
                rid=a, task_type=b, arrival=tm,
                deadline=tm + float(slack[b])))
        else:
            j = a
            req = router.running[j]
            lat = tm - req.start
            started = router.on_completion(
                j, success=tm <= req.deadline, latency=lat)
        for j, req in started:
            real = float(eet[req.task_type, j]) * rng.uniform(0.85, 1.25)
            heapq.heappush(events, (clock.t + real, 1, j, 0))

    m = router.metrics()
    print(f"heuristic={args.heuristic} archs={args.archs}")
    print(f"completion={m['collective_completion_rate']:.3f} "
          f"jain={m['jain_fairness']:.3f} "
          f"energy={m['energy']:.0f}J wasted={m['energy_wasted']:.0f}J")
    for i, a in enumerate(args.archs):
        print(f"  {a:22s} cr={m['completion_rate_by_type'][i]:.3f} "
              f"({int(m['completed'][i])}/{int(m['arrived'][i])})")


if __name__ == "__main__":
    main()
