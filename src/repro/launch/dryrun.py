"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder-device flag before ANY jax import (jax locks the
device count at first init) — hence the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
Each cell appends one JSON line (memory analysis, cost analysis, collective
bytes, roofline terms) so interrupted sweeps resume cheaply.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry, shapes as shp
from repro.datapipe.synthetic import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim.adamw import AdamW
from repro.roofline import analysis as ra, hlo_graph, jaxpr_cost
from repro.train.steps import make_serve_steps, make_train_step

DEFAULT_ACCUM = {"train_4k": 8}


def serve_batch_specs(cfg, shape):
    """ShapeDtypeStruct inputs for prefill cells."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model),
                                           jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, S // 2), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S - cfg.n_patches), jnp.int32),
            "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model),
                                            jnp.bfloat16),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_specs(cfg, shape):
    B, S = shape.global_batch, shape.seq_len
    max_seq = S // 2 if cfg.family == "audio" else S
    cache = jax.eval_shape(lambda: tf.init_cache(cfg, B, max_seq))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return cache, tokens


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               accum: int | None = None, cfg=None):
    cfg = cfg or registry.get_config(arch)
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}

    pshapes = tf.param_shapes(cfg)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            accum = accum or DEFAULT_ACCUM.get(shape_name, 8)
            step = make_train_step(cfg, AdamW(), mesh, donate=False)
            specs = input_specs(cfg, shape, accum=accum)
            opt_shapes = jax.eval_shape(AdamW().init, pshapes)
            jitted = step.jit_for(specs)
            lowered = jitted.lower(pshapes, opt_shapes, specs)
            cost_fn, cost_args = step, (pshapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            prefill_jit_for, _ = make_serve_steps(cfg, mesh)
            specs = serve_batch_specs(cfg, shape)
            max_seq = (shape.seq_len // 2 if cfg.family == "audio"
                       else shape.seq_len)
            jitted = prefill_jit_for(specs, max_seq)
            lowered = jitted.lower(pshapes, specs)

            def _prefill_raw(p, b):
                return tf.prefill(cfg, p, b, max_seq)
            cost_fn, cost_args = _prefill_raw, (pshapes, specs)
        else:  # decode
            _, decode_jit_for = make_serve_steps(cfg, mesh)
            cache_shapes, tok = decode_specs(cfg, shape)
            jitted = decode_jit_for(cache_shapes, tok)
            lowered = jitted.lower(pshapes, cache_shapes, tok)

            def _decode_raw(p, c, t):
                return tf.decode_step(cfg, p, c, t)
            cost_fn, cost_args = _decode_raw, (pshapes, cache_shapes, tok)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        } if mem is not None else None
    except Exception:
        mem_stats = None
    hlo = compiled.as_text()
    coll_flat = ra.collective_bytes(hlo)
    coll_weighted = hlo_graph.collective_bytes_weighted(hlo)
    # trip-count-exact global flops/bytes from the jaxpr walk
    jc = jaxpr_cost.jaxpr_cost(cost_fn, *cost_args)
    chips = mesh.devices.size
    mf = ra.model_flops_for(cfg, shape)
    roof = ra.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=jc["flops"] / chips,
        bytes_per_device=jc["bytes"] / chips,
        coll_bytes_per_device=sum(coll_weighted.values()) / chips,
        model_flops=mf,
    )
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
        "jaxpr_cost": jc,
        "memory": mem_stats,
        "collective_bytes": coll_flat,
        "collective_bytes_weighted": coll_weighted,
        "roofline": roof.row(),
        "hlo_lines": len(hlo.splitlines()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(shp.SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--variant", choices=("base", "opt"), default="base",
                    help="opt: beyond-paper optimized config "
                         "(vocab padded to a TP-shardable multiple)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    outfile = outdir / "cells.jsonl"
    done = set()
    if outfile.exists():
        for line in outfile.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    meshes = {}
    mesh_names = (["pod", "multipod"] if args.mesh == "both"
                  else [args.mesh])
    for mn in mesh_names:
        meshes[mn] = make_production_mesh(multi_pod=(mn == "multipod"))

    if args.all:
        cells = [(a, s) for a in registry.ARCH_IDS for s in shp.SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape_name in cells:
        for mn, mesh in meshes.items():
            if (arch, shape_name, mn) in done:
                print(f"[cached] {arch} x {shape_name} x {mn}")
                continue
            print(f"[lower+compile] {arch} x {shape_name} x {mn} ...",
                  flush=True)
            cfg = registry.get_config(arch)
            if args.variant == "opt":
                cfg = cfg.scaled(pad_vocab_to=256)
            try:
                rec = lower_cell(arch, shape_name, mesh, mn,
                                 accum=args.accum, cfg=cfg)
            except Exception as e:  # a failed cell is a bug: record it
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape_name, "mesh": mn,
                       "status": "fail", "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            with open(outfile, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"  -> {rec['status']}"
                  + (f" compile {rec.get('compile_s')}s" if rec.get(
                      "compile_s") else ""), flush=True)
    print(f"done; {n_fail} failures -> {outfile}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
