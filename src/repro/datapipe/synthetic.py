"""Synthetic data generation.

Two producers live here:

  * ``SyntheticLM`` / ``Prefetcher`` / ``input_specs`` — the deterministic
    LM token pipeline used by the model-substrate examples (hash-based,
    reproducible across restarts — checkpoint/restart tests rely on this).
  * ``trace_stack`` — batched scheduling-workload synthesis for the
    Monte-Carlo sweep subsystem (`repro.experiments`): a full
    (arrival-rate x replicate) grid of traces under one PRNG key, shaped
    for a single vmapped simulation. Synthesis is delegated to a
    :class:`repro.scenarios.Scenario` (default: the paper's Poisson
    workload), so the same CRN grid machinery serves bursty, diurnal,
    flash-crowd, drifting-mix, ... workloads unchanged.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def trace_stack(key, rates, reps, n_tasks, eet, *, cv_run: float = 0.1,
                type_probs=None, scenario=None, n_task_types=None):
    """Synthesize the full sweep grid of workload traces under one PRNG key.

    Replicate ``k`` uses the same subkey at every arrival rate (common
    random numbers): the task-type and actual-runtime draws are shared
    across rates, with only the arrival process seeing the rate. This
    couples the sweep's rate axis the way the paper couples its heuristic
    axis (every heuristic sees identical traces), which sharpens
    rate-to-rate comparisons at a given replicate count — and it holds for
    every scenario, because the rate only ever enters the arrival
    component.

    Args:
      key: a single ``jax.random.PRNGKey``; the only seed material used.
      rates: sequence of R nominal arrival rates (tasks/sec).
      reps: K i.i.d. replicates per rate.
      n_tasks: N tasks per trace.
      eet: (S, M) expected-execution-time matrix (seconds).
      cv_run: sweep-level coefficient of variation of actual runtimes
        (runtime models with their own dispersion parameters ignore it).
      type_probs: optional (S,) task-type mix shorthand; swaps the
        scenario's mix for a ``WeightedMix`` when given.
      scenario: a :class:`repro.scenarios.Scenario`, a registered scenario
        name, or ``None`` for the paper's Poisson default.
      n_task_types: optional override of the type count (default: the
        EET's row count S).

    Returns:
      A ``repro.core.types.Trace`` whose leaves carry leading dims (R, K):
      arrival/task_type/deadline are (R, K, N) and exec_actual is
      (R, K, N, M). Flatten the first two dims for one big vmap, or index
      ``[r, k]`` for a single trace.
    """
    from repro import scenarios as scenarios_mod

    if scenario is None:
        scenario = scenarios_mod.DEFAULT
    elif isinstance(scenario, str):
        scenario = scenarios_mod.get(scenario)
    if type_probs is not None:
        scenario = scenarios_mod.replace(
            scenario, mix=scenarios_mod.mix_from_probs(tuple(type_probs))
        )
    return scenario.stack(key, rates, reps, n_tasks, eet, cv_run=cv_run,
                          n_task_types=n_task_types)


class SyntheticLM:
    """An infinite LM stream: batch(step) is a pure function of (seed, step).

    Markov-ish structure (token t+1 correlates with t) so the loss actually
    decreases during the example runs instead of sitting at log V.
    """

    def __init__(self, cfg, batch: int, seq: int, *, seed: int = 0,
                 accum: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.accum = accum

    def batch_at(self, step: int):
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        V = cfg.vocab_size
        base = rng.integers(0, V, size=(self.batch, 1), dtype=np.int32)
        drift = rng.integers(0, 17, size=(self.batch, self.seq),
                             dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % V
        out = {"tokens": toks.astype(np.int32)}
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (self.batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model)).astype(np.float32) * 0.02
        if self.accum > 1:
            out = {
                k: v.reshape(self.accum, self.batch // self.accum,
                             *v.shape[1:])
                for k, v in out.items()
            }
        else:
            out = {k: v[None] for k, v in out.items()}
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any batch iterator."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def input_specs(cfg, shape, *, accum: int = 1, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    B, S = shape.global_batch, shape.seq_len
    mb = B // accum
    specs = {"tokens": jax.ShapeDtypeStruct((accum, mb, S), dtype)}
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (accum, mb, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (accum, mb, S // 2, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((accum, mb, S // 2), dtype)
    return specs
