"""Synthetic sharded data pipeline.

Deterministic per-step token batches (hash-based, reproducible across
restarts — checkpoint/restart tests rely on this), with modality extras for
the VLM / audio stubs, background prefetch, and grad-accum reshaping.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """An infinite LM stream: batch(step) is a pure function of (seed, step).

    Markov-ish structure (token t+1 correlates with t) so the loss actually
    decreases during the example runs instead of sitting at log V.
    """

    def __init__(self, cfg, batch: int, seq: int, *, seed: int = 0,
                 accum: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.accum = accum

    def batch_at(self, step: int):
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        V = cfg.vocab_size
        base = rng.integers(0, V, size=(self.batch, 1), dtype=np.int32)
        drift = rng.integers(0, 17, size=(self.batch, self.seq),
                             dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % V
        out = {"tokens": toks.astype(np.int32)}
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (self.batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model)).astype(np.float32) * 0.02
        if self.accum > 1:
            out = {
                k: v.reshape(self.accum, self.batch // self.accum,
                             *v.shape[1:])
                for k, v in out.items()
            }
        else:
            out = {k: v[None] for k, v in out.items()}
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any batch iterator."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def input_specs(cfg, shape, *, accum: int = 1, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    B, S = shape.global_batch, shape.seq_len
    mb = B // accum
    specs = {"tokens": jax.ShapeDtypeStruct((accum, mb, S), dtype)}
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (accum, mb, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (accum, mb, S // 2, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((accum, mb, S // 2), dtype)
    return specs
