"""internvl2-1b [vlm] — 24L d896 14H (GQA kv=2) ff=4864 vocab=151655.

InternViT frontend is a STUB: input_specs() provides 256 precomputed patch
embeddings prepended to the text tokens; backbone is the Qwen2-0.5B-class LM.
[arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151_655, qkv_bias=True, n_patches=256,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128, vocab_size=256,
    n_patches=16,
)
