"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) expert ff=512
vocab=49155, MoE 40 experts top-8.

The assignment string says "MoE 40e top-8" (the bracketed hf pointer is the
32-expert 1b sibling); the explicit config string wins — recorded in
DESIGN.md. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49_155,
    n_experts=40, experts_per_token=8,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_ff=64, vocab_size=256,
    n_experts=8, experts_per_token=2,
)
