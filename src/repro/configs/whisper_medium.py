"""whisper-medium [audio] — 24L d1024 16H (kv=16) ff=4096 vocab=51865.

Encoder-decoder backbone; conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (S_enc = seq_len // 2) and the decoder sees
seq_len // 2 positions, so a shape cell exercises ~seq_len total positions.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51_865, encoder_layers=24,
    mlp="gelu", norm="layernorm",
)

SMOKE = CONFIG.scaled(
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
)
