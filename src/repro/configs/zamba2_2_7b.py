"""zamba2-2.7b [hybrid] — 54L d2560 32H (kv=32) ff=10240 vocab=32000,
ssm_state=64. Mamba2 backbone + one SHARED attention block invoked every 6
layers (9 invocations with per-invocation norms). [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32_000,
    ssm_state=64, attn_every=6,
)

SMOKE = CONFIG.scaled(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
    attn_every=3, ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
)
