"""Unified model configuration for all assigned architectures."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pad_vocab_to: int = 1       # pad embedding rows to a multiple (TP shard)
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 4096       # tokens per dispatch group (linear dispatch)
    # SSM (Mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0         # zamba2: shared attn block period (layers)
    # xLSTM
    slstm_every: int = 0        # sLSTM at every k-th layer (rest mLSTM)
    # encoder-decoder (whisper backbone)
    encoder_layers: int = 0
    # vlm
    n_patches: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # kernel selection: xla | pallas | pallas_interpret
    attn_impl: str = "xla"
    ssm_impl: str = "xla"
    # distribution
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to
        return -(-self.vocab_size // m) * m

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def scaled(self, **kw) -> "ModelConfig":
        """A reduced sibling config (smoke tests) — same family/topology."""
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (matches init; used for 6ND rooflines)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        if self.mlp == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp + 2 * d
            body = self.n_layers * per_layer
        elif self.family == "moe":
            router = d * self.n_experts
            emlp = self.n_experts * (3 * d * ff)
            body = self.n_layers * (attn + emlp + router + 2 * d)
        elif self.family == "ssm":  # xLSTM
            di = self.d_model  # mLSTM/sLSTM operate at model width here
            per = 4 * d * di + di * d + 3 * d  # qkv+gates approx + out + norms
            mlp_x = 2 * d * int(2.67 * d)
            body = self.n_layers * (per + mlp_x)
        elif self.family == "hybrid":  # zamba2
            din, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * din + 2 * ds + nh)
            out_proj = din * d
            mamba = in_proj + out_proj + self.ssm_conv * (din + 2 * ds) + 2 * nh
            n_attn = self.n_layers // max(self.attn_every, 1)
            shared = attn + mlp  # one shared block (counted once)
            body = self.n_layers * (mamba + 2 * d) + shared + n_attn * 2 * d
        elif self.family == "audio":
            body = (self.n_layers + self.encoder_layers) * (attn + mlp + 2 * d)
            body += self.n_layers * (attn + d)  # cross-attention
        else:
            raise ValueError(self.family)
        emb = self.vocab_size * d
        if not self.tie_embeddings:
            emb *= 2
        return body + emb

    def active_params(self) -> int:
        """Activated parameters per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        full = self.n_params()
        unused = self.n_layers * (
            (self.n_experts - self.experts_per_token) * 3 * d * ff
        )
        return full - unused
