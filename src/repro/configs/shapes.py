"""Assigned input shapes and per-architecture applicability."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Families with sub-quadratic sequence handling (O(1)/O(w) decode state) run
# long_500k; pure full-attention archs skip it (DESIGN.md §shape policy).
SUBQUADRATIC_FAMILIES = {"ssm", "hybrid"}


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "SKIP(full-attention): 512k dense KV cache infeasible"
    return True, ""


def cells(cfg):
    """All 4 assigned shape cells for an arch, with skip annotations."""
    out = []
    for name in SHAPES:
        ok, reason = applicable(cfg, name)
        out.append((name, ok, reason))
    return out
