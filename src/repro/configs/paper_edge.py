"""The paper's own workload: the 4x4 synthetic HEC system (Table I) plus the
AWS scenario. Exposed as a 'config' so --arch paper-edge drives the simulator
through the same launcher plumbing as the LM architectures.

Systems and workload scenarios both resolve through the
:mod:`repro.scenarios` registries; the constants below are the paper's
operating points.
"""
from repro import scenarios
from repro.core import api

SYSTEM = api.paper_system()
AWS = api.aws_system()

#: The Sec. VI-A workload recipe (stationary Poisson / uniform mix /
#: Eq. 4 deadlines / Gamma runtimes) — ``SweepSpec``'s default.
SCENARIO = scenarios.get("poisson")

#: Beyond-paper stress workloads registered out of the box.
STRESS_SCENARIOS = tuple(
    name for name in scenarios.list_scenarios() if name != "poisson"
)
