"""The paper's own workload: the 4x4 synthetic HEC system (Table I) plus the
AWS scenario. Exposed as a 'config' so --arch paper-edge drives the simulator
through the same launcher plumbing as the LM architectures."""
from repro.core import api

SYSTEM = api.paper_system()
AWS = api.aws_system()
