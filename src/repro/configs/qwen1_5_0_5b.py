"""qwen1.5-0.5b [dense] — 24L d1024 16H (kv=16 MHA) ff=2816 vocab=151936.

QKV bias path exercised. [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab_size=151_936, qkv_bias=True,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=384,
)
