"""command-r-35b [dense] — 40L d8192 64H (GQA kv=8) ff=22528 vocab=256000.

GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256_000, qkv_bias=False,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=352,
    vocab_size=512,
)
