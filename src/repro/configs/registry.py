"""Architecture registry: --arch <id> -> ModelConfig (full + smoke)."""
from __future__ import annotations

import importlib

_MODULES = {
    "command-r-35b": "command_r_35b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-1b": "internvl2_1b",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _mod(arch).CONFIG


def get_smoke_config(arch: str):
    return _mod(arch).SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
