"""xlstm-125m [ssm] — 12L d768 4H ff=0 vocab=50304.

sLSTM + mLSTM blocks. slstm_every=2: odd layers sLSTM, even layers mLSTM
(6+6 of the 12). d_ff=0 per the assignment: the xLSTM blocks carry their own
up/down projections instead of a separate MLP. [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304, slstm_every=2,
)

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=256,
)
