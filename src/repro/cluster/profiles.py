"""Machine profiles for the serving cluster: the TPU-fleet analogue of the
paper's heterogeneous edge boards.

A *machine type* is a device group with (peak FLOP/s, HBM bandwidth, dynamic
power, idle power). The EET matrix — the paper's profiling input — is
*derived from the roofline model* per (architecture x machine): expected
latency of one request = max(compute term, memory term) for the request's
token count, exactly the §Roofline math at machine granularity.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    name: str
    chips: int
    peak_flops: float     # per chip, bf16
    hbm_bw: float         # bytes/s per chip
    p_dyn: float          # watts per chip under load
    p_idle: float         # watts per chip idle

    @property
    def total_flops(self):
        return self.chips * self.peak_flops

    @property
    def total_bw(self):
        return self.chips * self.hbm_bw


# A plausible heterogeneous serving fleet (per-chip numbers):
#   v5e slice  — the paper's "GPU": fast, power-hungry
#   v5-lite    — mid generation
#   cpu-host   — the paper's "slow but frugal" board
FLEET = (
    MachineProfile("v5e-4", chips=4, peak_flops=197e12, hbm_bw=819e9,
                   p_dyn=170.0, p_idle=35.0),
    MachineProfile("v5e-1", chips=1, peak_flops=197e12, hbm_bw=819e9,
                   p_dyn=180.0, p_idle=38.0),
    MachineProfile("v4-lite", chips=2, peak_flops=110e12, hbm_bw=600e9,
                   p_dyn=140.0, p_idle=30.0),
    MachineProfile("cpu-host", chips=1, peak_flops=3e12, hbm_bw=150e9,
                   p_dyn=60.0, p_idle=10.0),
)


def request_cost(cfg, n_tokens: int, *, decode: bool = False):
    """(flops, hbm_bytes) of one request on an architecture."""
    n_active = cfg.active_params()
    if decode:
        flops = 2.0 * n_active * n_tokens
        byts = 2.0 * n_active * n_tokens      # weights re-streamed per token
    else:
        flops = 2.0 * n_active * n_tokens
        byts = 2.0 * n_active                 # one weight pass (batched)
    return flops, byts


def eet_from_roofline(cfgs, machines=FLEET, *, n_tokens=256, decode=False,
                      overhead_s=0.002):
    """EET[i, j] = roofline latency of arch i's request on machine j."""
    eet = np.zeros((len(cfgs), len(machines)), np.float32)
    for i, cfg in enumerate(cfgs):
        flops, byts = request_cost(cfg, n_tokens, decode=decode)
        for j, m in enumerate(machines):
            t = max(flops / m.total_flops, byts / m.total_bw) + overhead_s
            eet[i, j] = t
    return eet


def power_vectors(machines=FLEET):
    p_dyn = np.array([m.p_dyn * m.chips for m in machines], np.float32)
    p_idle = np.array([m.p_idle * m.chips for m in machines], np.float32)
    return p_dyn, p_idle
