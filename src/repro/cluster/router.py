"""FELARE as the first-class request router of the serving runtime.

The router owns: per-machine bounded local queues, the EET matrix (roofline-
seeded, refined online by an EMA of observed latencies — which doubles as
STRAGGLER MITIGATION: a slow group's EET row grows, its c_ij estimates grow,
and FELARE organically routes around it while suffered-type boosting prevents
starvation), per-type completion-rate tracking, and the energy ledger.

``Router.on_request`` / ``on_completion`` mirror the paper's mapping events;
the mapping decision itself is the same jitted policy the simulator uses
(resolved through the :mod:`repro.core.policy` registry, so user-registered
policies drive the router too) — one code path from the paper's Algorithm 1
to the production router.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import equations, fairness, policy
from repro.core.policy import MachineView
from repro.core.types import SystemArrays


@dataclasses.dataclass
class Request:
    rid: int
    task_type: int
    arrival: float
    deadline: float
    payload: object = None
    # lifecycle
    machine: int | None = None
    start: float | None = None
    finish: float | None = None
    status: str = "pending"   # pending|queued|running|completed|missed|cancelled


class Router:
    def __init__(self, eet: np.ndarray, p_dyn, p_idle, *, queue_size=2,
                 heuristic: str = "FELARE", fairness_factor: float = 1.0,
                 eet_ema: float = 0.2, now_fn: Callable[[], float] = time.monotonic):
        self.eet = np.asarray(eet, np.float32).copy()
        self.p_dyn = np.asarray(p_dyn, np.float32)
        self.p_idle = np.asarray(p_idle, np.float32)
        self.S, self.M = self.eet.shape
        self.Q = queue_size
        self.heuristic = policy.get(heuristic)
        self.f = fairness_factor
        self.ema = eet_ema
        self.now_fn = now_fn

        self.pending: dict[int, Request] = {}
        self.queues: list[deque[Request]] = [deque() for _ in range(self.M)]
        self.running: list[Request | None] = [None] * self.M
        self.run_end_exp = np.zeros(self.M, np.float64)
        self.completed = np.zeros(self.S, np.int64)
        self.missed = np.zeros(self.S, np.int64)
        self.cancelled = np.zeros(self.S, np.int64)
        self.arrived = np.zeros(self.S, np.int64)
        self.energy = 0.0
        self.energy_wasted = 0.0

    # ------------------------------------------------------------------
    def on_request(self, req: Request):
        self.pending[req.rid] = req
        self.arrived[req.task_type] += 1
        return self._map_event()

    def on_completion(self, machine: int, *, success: bool, latency: float):
        req = self.running[machine]
        assert req is not None
        now = self.now_fn()
        req.finish = now
        req.status = "completed" if success else "missed"
        dur = now - (req.start if req.start is not None else now)
        e = self.p_dyn[machine] * dur
        self.energy += e
        if success:
            self.completed[req.task_type] += 1
        else:
            self.missed[req.task_type] += 1
            self.energy_wasted += e
        # EET EMA refresh -> straggler adaptation
        i, j = req.task_type, machine
        self.eet[i, j] = ((1 - self.ema) * self.eet[i, j]
                          + self.ema * latency)
        self.running[machine] = None
        started = self._start_tasks()
        return self._map_event() + started

    # ------------------------------------------------------------------
    def _suffered(self):
        return np.asarray(fairness.suffered_types(
            jnp.asarray(self.completed.astype(np.float32)),
            jnp.asarray(self.arrived.astype(np.float32)), self.f))

    def _map_event(self):
        """Run one mapping event over the live pending set. Returns newly
        started requests (machine, Request) for the executor to launch."""
        now = self.now_fn()
        pend_list = list(self.pending.values())
        queued_reqs = [r for q in self.queues for r in q]
        allr = pend_list + queued_reqs
        n = len(allr)
        if n == 0:
            return self._start_tasks()
        ttype = jnp.asarray([r.task_type for r in allr], jnp.int32)
        deadline = jnp.asarray([r.deadline for r in allr], jnp.float32)
        pending_mask = jnp.asarray(
            [r.status == "pending" for r in allr])
        # id -> flat index map: O(n) once, instead of O(n^2) list.index
        # scans — which also mis-resolved when two requests compared equal
        # (Request is a dataclass; .index returns the *first* equal one).
        idx_of = {id(r): k for k, r in enumerate(allr)}
        queue = np.full((self.M, self.Q), -1, np.int32)
        for j, q in enumerate(self.queues):
            for s, req in enumerate(q):
                queue[j, s] = idx_of[id(req)]
        avail = np.where(
            [r is not None for r in self.running],
            np.maximum(self.run_end_exp, now), now).astype(np.float32)
        view = MachineView(
            avail_base=jnp.asarray(avail),
            queue=jnp.asarray(queue),
            qlen=jnp.asarray([len(q) for q in self.queues], jnp.int32),
        )
        sysarr = SystemArrays(
            eet=jnp.asarray(self.eet), p_dyn=jnp.asarray(self.p_dyn),
            p_idle=jnp.asarray(self.p_idle))
        action = self.heuristic(
            jnp.float32(now), pending_mask, ttype, deadline, view, sysarr,
            jnp.asarray(self._suffered()))

        # queue evictions
        qd = np.asarray(action.queue_drop)
        for j in range(self.M):
            victims = [s for s in range(self.Q)
                       if s < len(self.queues[j]) and qd[j, s]]
            for s in reversed(victims):
                victim = self.queues[j][s]
                del self.queues[j][s]
                victim.status = "cancelled"
                self.cancelled[victim.task_type] += 1
        # drops
        drops = np.asarray(action.drop)
        for k, r in enumerate(allr):
            if k < len(pend_list) and drops[k] and r.status == "pending":
                r.status = "cancelled"
                self.cancelled[r.task_type] += 1
                self.pending.pop(r.rid, None)
        # assignments
        assign = np.asarray(action.assign)
        for j in range(self.M):
            k = int(assign[j])
            if k < 0 or k >= len(allr):
                continue
            r = allr[k]
            if r.status == "pending" and len(self.queues[j]) < self.Q:
                r.status = "queued"
                r.machine = j
                self.queues[j].append(r)
                self.pending.pop(r.rid, None)
        return self._start_tasks()

    def _start_tasks(self):
        """Pop queue heads onto idle machines; returns [(machine, Request)]."""
        now = self.now_fn()
        started = []
        for j in range(self.M):
            while self.running[j] is None and self.queues[j]:
                req = self.queues[j].popleft()
                if now >= req.deadline:
                    req.status = "missed"
                    self.missed[req.task_type] += 1
                    continue
                req.status = "running"
                req.start = now
                self.running[j] = req
                self.run_end_exp[j] = float(equations.completion_time(
                    now, self.eet[req.task_type, j], req.deadline))
                started.append((j, req))
        return started

    # ------------------------------------------------------------------
    def metrics(self):
        cr = np.where(self.arrived > 0,
                      self.completed / np.maximum(self.arrived, 1), 1.0)
        return {
            "completed": self.completed.copy(),
            "missed": self.missed.copy(),
            "cancelled": self.cancelled.copy(),
            "arrived": self.arrived.copy(),
            "completion_rate_by_type": cr,
            "collective_completion_rate":
                float(self.completed.sum() / max(self.arrived.sum(), 1)),
            "jain_fairness": float(fairness.jain_index(jnp.asarray(
                cr.astype(np.float32)))),
            "energy": self.energy,
            "energy_wasted": self.energy_wasted,
            "eet": self.eet.copy(),
        }
