"""Generate markdown tables for EXPERIMENTS.md from dry-run JSONL artifacts."""
import json, sys, pathlib

def load(path):
    by = {}
    p = pathlib.Path(path)
    if not p.exists(): return by
    for line in p.read_text().splitlines():
        try: r = json.loads(line)
        except json.JSONDecodeError: continue
        by[(r["arch"], r["shape"], r["mesh"])] = r
    return by

def roofline_md(by, mesh):
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant | useful | MFU@roofline |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for (a, s, m), r in sorted(by.items()):
        if m != mesh: continue
        if r["status"] == "skip":
            out.append(f"| {a} | {s} | — | — | — | SKIP(full-attn) | — | — |"); continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | FAIL | | | | | |"); continue
        ro = r["roofline"]
        out.append(f"| {a} | {s} | {ro['t_comp_s']*1e3:.2f} | {ro['t_mem_s']*1e3:.2f} | "
                   f"{ro['t_coll_s']*1e3:.2f} | {ro['dominant']} | {ro['useful_frac']:.3f} | {ro['mfu']:.4f} |")
    return "\n".join(out)

def dryrun_md(by):
    out = ["| arch | shape | pod | multipod | compile (s) | HLO lines | temp bytes/dev |",
           "|---|---|---|---|---:|---:|---:|"]
    archs = sorted(set(k[0] for k in by))
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in archs:
        for s in shapes:
            p = by.get((a, s, "pod")); m = by.get((a, s, "multipod"))
            if p is None: continue
            st = lambda r: {"ok": "✓", "skip": "skip", "fail": "✗"}.get(r["status"], "?") if r else "—"
            comp = p.get("compile_s", "")
            hlo = p.get("hlo_lines", "")
            mem = p.get("memory") or {}
            tmp = mem.get("temp_size_in_bytes", "")
            tmp = f"{tmp/2**30:.2f} GiB" if tmp != "" else ""
            out.append(f"| {a} | {s} | {st(p)} | {st(m)} | {comp} | {hlo} | {tmp} |")
    return "\n".join(out)

if __name__ == "__main__":
    kind, path, mesh = sys.argv[1], sys.argv[2], (sys.argv[3] if len(sys.argv) > 3 else "pod")
    by = load(path)
    print(roofline_md(by, mesh) if kind == "roofline" else dryrun_md(by))
